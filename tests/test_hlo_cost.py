"""Loop-aware HLO cost analyzer: exactness on known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compat, hlo_cost, locality


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


X = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def test_direct_matmul_flops():
    def f(x, w):
        for _ in range(10):
            x = x @ w
        return x
    r = hlo_cost.analyze(_text(f, X, X))
    assert r["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_scan_trip_count_multiplies():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    r = hlo_cost.analyze(_text(f, X, X))
    assert r["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    r = hlo_cost.analyze(_text(f, X, X))
    assert r["flops"] == pytest.approx(20 * 2 * 128 ** 3, rel=0.01)


def test_builtin_cost_analysis_undercounts_loops():
    """Documents WHY hlo_cost exists: XLA's visitor counts bodies once."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    compiled = jax.jit(f).lower(X, X).compile()
    builtin = locality.extract_costs(compiled)["flops"]
    assert builtin < 0.2 * (10 * 2 * 128 ** 3)


def test_scan_bytes_linear_not_quadratic():
    """In-place DUS accounting: stacking N slices costs O(N), not O(N^2)."""
    def f(x):
        def body(c, _):
            c = c * 2.0
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=64)
        return ys
    r = hlo_cost.analyze(_text(f, X))
    slice_bytes = 128 * 128 * 4
    # the per-step constant depends on how many copies/fusions this XLA
    # build emits around the DUS (observed 8.0-8.1x across versions); the
    # claim under test is linearity, so cap at a loose 16x per step —
    # quadratic stacking would be ~32x here (64 slices * avg half stack)
    # and grows with length, a constant factor does not
    assert r["bytes"] < 64 * slice_bytes * 16
    assert r["bytes"] >= 64 * slice_bytes        # at least writes the stack


def _sharded_text(n_dev, fn, arg_specs, in_specs, out_spec):
    import os
    mesh = compat.make_mesh((2, n_dev // 2), ("data", "model"))
    with compat.set_mesh(mesh):
        return jax.jit(fn, in_shardings=in_specs,
                       out_shardings=out_spec).lower(*arg_specs).compile().as_text()


def test_collective_accounting_smoke():
    """all-reduce of a known tensor size is counted with correct bytes."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under dryrun env)")


def test_locality_report_parsing():
    txt = """
ENTRY %main (p0: f32[16,16]) -> f32[16,16] {
  %p0 = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
}
"""
    rep = locality.analyze_hlo(txt)
    assert rep.count == 1
    assert rep.by_kind["all-reduce"].operand_bytes == 16 * 16 * 4
    # ring all-reduce: 2 (g-1)/g x operand
    assert rep.wire_bytes == pytest.approx(16 * 16 * 4 * 2 * 3 / 4)


def test_p_local_metric():
    rep = locality.LocalityReport(by_kind={
        "all-gather": locality.CollectiveStats(1, 100.0, 300.0)})
    assert rep.p_local(3000.0) == pytest.approx(0.9)
    assert rep.p_local(0.0) == 1.0
