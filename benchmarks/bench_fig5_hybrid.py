"""Paper Fig. 5 — hybrid addressing: throughput/latency vs p_local.

Two parts:
  (a) the paper-faithful Top_H traffic model swept over p_local;
  (b) the TPU measurement: compile the same small model under two region
      plans (INTERLEAVED weights = FSDP vs maximally-local = TP-only) on 8
      host devices and report the *measured* collective bytes from HLO —
      the GSPMD p_local experiment. Run in a subprocess because the device
      count must be fixed before jax initializes.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.core.interconnect import TOP_H, TopologyModel

_SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get, SHAPES
    from repro.core import addressing, compat, hlo_cost
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_test_mesh
    import dataclasses

    out = {}
    # remote (global) vs local MoE dispatch: the p_local lever on a real
    # model (mixtral's router/dispatch traffic either crosses shards or not)
    for name, local in [("interleaved", False), ("local", True)]:
        cfg = dataclasses.replace(get("mixtral-8x7b"),
                                  moe_local_dispatch=local, grad_accum=1,
                                  n_layers=4)
        mesh = make_test_mesh()
        rules = addressing.default_rules(mesh, overrides=cfg.rules_overrides)
        fn, args, in_sh, out_sh, donate = dr.build_cell(
            cfg, SHAPES["train_4k"], mesh, rules)
        with compat.set_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                               donate_argnums=donate).lower(*args).compile()
        costs = hlo_cost.analyze(compiled.as_text())
        out[name] = {"collective_bytes": costs["collective_operand_bytes"],
                     "total_bytes": costs["bytes"]}
    print(json.dumps(out))
""")


def model_sweep() -> list[str]:
    m = TopologyModel(TOP_H)
    lines = []
    for p in (0.0, 0.125, 0.25, 0.5, 0.75):
        acc = m.accepted_load(2.0, p_local=p)
        lat = m.avg_latency(0.3, p_local=p)
        lines.append(f"fig5/model_p{p:.3f},0,"
                     f"accepted={acc:.3f};latency={lat:.2f}cyc")
    gain = m.accepted_load(2.0, 0.25) / m.accepted_load(2.0, 0.0) - 1
    lines.append(f"fig5/paper_claim_25pct,0,gain={gain * 100:.1f}pct"
                 f";paper=27pct")
    return lines


def measured_production() -> list[str] | None:
    """256-chip measurement from the committed dry-run variants."""
    res = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    base = res / "mixtral-8x7b__train_4k__single.json"
    loc = res / "mixtral-8x7b__train_4k__single__localmoe.json"
    if not (base.exists() and loc.exists()):
        return None
    b = json.loads(base.read_text())
    l = json.loads(loc.read_text())
    cb = b["hlo"]["collective_operand_bytes_per_device"]
    cl = l["hlo"]["collective_operand_bytes_per_device"]
    tb = b["hlo"]["bytes_per_device"]
    tl = l["hlo"]["bytes_per_device"]
    return [f"fig5/measured256_interleaved,0,p_local={1 - cb / tb:.4f};"
            f"coll_bytes={cb:.3e}",
            f"fig5/measured256_local,0,p_local={1 - cl / tl:.4f};"
            f"coll_bytes={cl:.3e}",
            f"fig5/measured256_gain,0,collective_reduction={cb / cl:.2f}x"]


def measured(timeout: int = 900) -> list[str]:
    prod = measured_production()
    if prod is not None:
        return prod
    src = Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src))
    try:
        out = subprocess.run([sys.executable, "-c", _SUB], env=env,
                             capture_output=True, text=True, timeout=timeout)
        data = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:  # pragma: no cover
        return [f"fig5/measured,0,skipped({type(e).__name__})"]
    il = data["interleaved"]
    lc = data["local"]
    p_il = 1 - il["collective_bytes"] / max(il["total_bytes"], 1)
    p_lc = 1 - lc["collective_bytes"] / max(lc["total_bytes"], 1)
    ratio = il["collective_bytes"] / max(lc["collective_bytes"], 1)
    return [f"fig5/measured_interleaved,0,p_local={p_il:.4f};"
            f"coll_bytes={il['collective_bytes']:.3e}",
            f"fig5/measured_local,0,p_local={p_lc:.4f};"
            f"coll_bytes={lc['collective_bytes']:.3e}",
            f"fig5/measured_gain,0,collective_reduction={ratio:.2f}x"]


def main(smoke: bool = False) -> list[str]:
    if smoke:
        # smoke lane: the analytic sweep only — the measured path compiles a
        # 4-layer mixtral in a subprocess and takes minutes
        return model_sweep() + ["fig5/measured,0,skipped(smoke)"]
    return model_sweep() + measured()


if __name__ == "__main__":
    print("\n".join(main()))
