"""Paper Fig. 16 — cost of individual operations, local vs remote.

The paper measures pJ/instruction and finds a remote load costs 1.8x a local
one. The TPU analogue of "energy per access" is time-per-byte on each level
of the hierarchy (HBM local, 1-hop ICI group, multi-hop ICI cluster, DCN
pod), for one 32-bit word per lane. We report ns/KiB and the remote/local
ratios, plus MAC-vs-load comparisons from the roofline constants.
"""

from __future__ import annotations

from repro.core import mesh as hw


def main() -> list[str]:
    kib = 1024.0
    local = kib / hw.HBM_BW                      # HBM
    group = kib / (2 * hw.ICI_BW_PER_LINK)       # 1-hop neighbor
    remote = 4 * 1e-6 / 8 + kib / hw.ICI_BW_PER_LINK   # multi-hop + α share
    pod = kib / hw.DCN_BW_PER_HOST
    mac = 2 * kib / 4 / hw.PEAK_FLOPS_BF16       # MACs on the same data
    lines = [
        f"fig16/local_load,{local * 1e9:.3f},ns_per_KiB(HBM)",
        f"fig16/group_load,{group * 1e9:.3f},ns_per_KiB(ICI-1hop)",
        f"fig16/remote_load,{remote * 1e9:.3f},ns_per_KiB(ICI-multihop)",
        f"fig16/pod_load,{pod * 1e9:.3f},ns_per_KiB(DCN)",
        f"fig16/mac,{mac * 1e9:.3f},ns_per_KiB_of_MACs",
        f"fig16/remote_over_local,{group / local:.2f},ratio(paper=1.8x)",
        f"fig16/pod_over_local,{pod / local:.1f},ratio",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
