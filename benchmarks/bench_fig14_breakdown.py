"""Paper Fig. 14 — where the cycles go, per (arch x shape).

The paper splits core activity into compute / control / stalls. Our roofline
split per dry-run cell: compute term share, memory term share, collective
term share (reads results/dryrun/*.json written by launch/dryrun.py).
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def main() -> list[str]:
    lines = []
    if not RESULTS.exists():
        return ["fig14/breakdown,0,skipped(no dry-run results)"]
    for p in sorted(RESULTS.glob("*__single.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok" or d.get("variant"):
            continue
        r = d["roofline"]
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        if total <= 0:
            continue
        lines.append(
            f"fig14/{d['arch']}/{d['shape']},0,"
            f"compute={r['compute_s'] / total:.3f};"
            f"memory={r['memory_s'] / total:.3f};"
            f"collective={r['collective_s'] / total:.3f};"
            f"dominant={r['dominant'].replace('_s', '')}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
