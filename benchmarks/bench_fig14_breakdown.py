"""Paper Fig. 14 — where the cycles go, per (arch x shape).

The paper splits core activity into compute / control / stalls. Our roofline
split per dry-run cell: compute term share, memory term share, collective
term share (reads results/dryrun/*.json written by launch/dryrun.py).

Second section: the fused-path traffic breakdown — modeled HBM bytes of
one transformer block through the fused producer–consumer kernels
(kernels/fused.py) vs the unfused composition of isolated kernels, per
representative arch. This is where the paper's "intermediates live in
shared L1" claim shows up as a bytes-moved number.

Third section: the same claim *measured* — wall time of the timed-tuned
fused rmsnorm+matmul against the tuned unfused composition, asserted (the
fused kernel must not lose to the composition it replaces; under modeled
tuning it used to, which is exactly why picks are raced now).
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

# (arch, b, s) cells for the fused-block traffic model; smoke shrinks them
_FUSED_CELLS = [("yi-34b", 1, 4096), ("qwen3-14b", 1, 4096),
                ("mixtral-8x7b", 1, 4096)]


def fused_block_rows(smoke: bool = False) -> list[str]:
    from repro.configs import registry
    from repro.kernels import fused

    lines = []
    for arch, b, s in _FUSED_CELLS[:1] if smoke else _FUSED_CELLS:
        cfg = registry.get(arch)
        if smoke:
            cfg, s = registry.get(arch + "-smoke"), 128
        t = fused.transformer_block_traffic(
            b, s, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.d_ff, attn_chunk=min(cfg.attn_chunk, s))
        lines.append(
            f"fig14_fused/{cfg.name}/b{b}s{s},0,"
            f"unfused_GB={t['unfused_bytes'] / 1e9:.3f};"
            f"fused_GB={t['fused_bytes'] / 1e9:.3f};"
            f"reduction={t['reduction']:.2f}x")
    return lines


# measured fused-vs-unfused must hold within this factor (timer noise;
# the fused kernel typically wins by >1.3x once its blocks are raced)
_FUSED_MEASURED_TOL = 1.25


def measured_fused_rows(smoke: bool = False) -> list[str]:
    """Measured (not modeled) fused-vs-unfused: the timed-tuned
    rmsnorm_matmul kernel against the tuned rmsnorm -> matmul composition,
    same operands, same median-of-repeats timer the autotuner races with.
    Asserts fused <= unfused * tol — with modeled picks the fused kernel
    lost this comparison; with raced picks it must not."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, pipeline as pp

    m, k, n = (128, 64, 128) if smoke else (512, 512, 512)
    ks = jax.random.split(jax.random.PRNGKey(14), 3)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    scale = jax.random.normal(ks[1], (k,), jnp.float32) * 0.1
    w = jax.random.normal(ks[2], (k, n), jnp.float32)
    reps = 1 if smoke else 3

    t_fused = pp.median_time(
        lambda: ops.tuned_call("rmsnorm_matmul", x, scale, w), reps=reps)
    t_unfused = pp.median_time(
        lambda: ops.tuned_call("matmul", ops.tuned_call("rmsnorm", x, scale),
                               w), reps=reps)
    assert t_fused <= t_unfused * _FUSED_MEASURED_TOL, (
        f"measured fused rmsnorm_matmul {t_fused * 1e6:.0f}us slower than "
        f"unfused composition {t_unfused * 1e6:.0f}us "
        f"(tol x{_FUSED_MEASURED_TOL}) — tuned blocks regressed")
    return [f"fig14_fused_measured/rmsnorm_matmul/m{m}k{k}n{n},"
            f"{t_fused * 1e6:.1f},"
            f"unfused_us={t_unfused * 1e6:.1f};"
            f"measured_ratio={t_unfused / max(t_fused, 1e-12):.2f}x"]


def main(smoke: bool = False) -> list[str]:
    lines = []
    if not RESULTS.exists():
        lines.append("fig14/breakdown,0,skipped(no dry-run results)")
    else:
        for p in sorted(RESULTS.glob("*__single.json")):
            d = json.loads(p.read_text())
            if d.get("status") != "ok" or d.get("variant"):
                continue
            r = d["roofline"]
            total = r["compute_s"] + r["memory_s"] + r["collective_s"]
            if total <= 0:
                continue
            lines.append(
                f"fig14/{d['arch']}/{d['shape']},0,"
                f"compute={r['compute_s'] / total:.3f};"
                f"memory={r['memory_s'] / total:.3f};"
                f"collective={r['collective_s'] / total:.3f};"
                f"dominant={r['dominant'].replace('_s', '')}")
    lines.extend(fused_block_rows(smoke))
    lines.extend(measured_fused_rows(smoke))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
