"""Paper Fig. 14 — where the cycles go, per (arch x shape).

The paper splits core activity into compute / control / stalls. Our roofline
split per dry-run cell: compute term share, memory term share, collective
term share (reads results/dryrun/*.json written by launch/dryrun.py).

Second section: the fused-path traffic breakdown — modeled HBM bytes of
one transformer block through the fused producer–consumer kernels
(kernels/fused.py) vs the unfused composition of isolated kernels, per
representative arch. This is where the paper's "intermediates live in
shared L1" claim shows up as a bytes-moved number.
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

# (arch, b, s) cells for the fused-block traffic model; smoke shrinks them
_FUSED_CELLS = [("yi-34b", 1, 4096), ("qwen3-14b", 1, 4096),
                ("mixtral-8x7b", 1, 4096)]


def fused_block_rows(smoke: bool = False) -> list[str]:
    from repro.configs import registry
    from repro.kernels import fused

    lines = []
    for arch, b, s in _FUSED_CELLS[:1] if smoke else _FUSED_CELLS:
        cfg = registry.get(arch)
        if smoke:
            cfg, s = registry.get(arch + "-smoke"), 128
        t = fused.transformer_block_traffic(
            b, s, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.d_ff, attn_chunk=min(cfg.attn_chunk, s))
        lines.append(
            f"fig14_fused/{cfg.name}/b{b}s{s},0,"
            f"unfused_GB={t['unfused_bytes'] / 1e9:.3f};"
            f"fused_GB={t['fused_bytes'] / 1e9:.3f};"
            f"reduction={t['reduction']:.2f}x")
    return lines


def main(smoke: bool = False) -> list[str]:
    lines = []
    if not RESULTS.exists():
        lines.append("fig14/breakdown,0,skipped(no dry-run results)")
    else:
        for p in sorted(RESULTS.glob("*__single.json")):
            d = json.loads(p.read_text())
            if d.get("status") != "ok" or d.get("variant"):
                continue
            r = d["roofline"]
            total = r["compute_s"] + r["memory_s"] + r["collective_s"]
            if total <= 0:
                continue
            lines.append(
                f"fig14/{d['arch']}/{d['shape']},0,"
                f"compute={r['compute_s'] / total:.3f};"
                f"memory={r['memory_s'] / total:.3f};"
                f"collective={r['collective_s'] / total:.3f};"
                f"dominant={r['dominant'].replace('_s', '')}")
    lines.extend(fused_block_rows(smoke))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
