"""Paper Fig. 13 — weak scaling with / without the final barrier.

Weak scaling on TPU: per-chip workload fixed (one matmul-suite round per
chip), chips swept 1 -> 256. Step time = max(compute, memory) + gradient
all-reduce (the "final synchronization barrier"); the without-barrier curve
drops the collective. Mirrors the paper's finding: compute-intense kernels
stay near-ideal, low-intensity ones lose ~25% to synchronization.
"""

from __future__ import annotations

from repro.core import mesh as hw
from repro.core.interconnect import CollectiveModel

import math

KERNELS = {
    # per-chip flops, per-chip HBM bytes, reduced bytes (the barrier payload)
    # the paper's kernels end in a *synchronization* barrier, not a
    # gradient reduction — only dotp reduces (its scalar result)
    "matmul": (2 * 2048 ** 3, 3 * 2048 * 2048 * 2, 0),
    "2dconv": (2 * 9 * 4096 * 4096, 2 * 4096 * 4096 * 2, 0),
    "dct": (4 * (4096 * 4096 // 64) * 8 ** 3, 2 * 4096 * 4096 * 2, 0),
    "axpy": (2 * (1 << 22), 3 * (1 << 22) * 2, 0),
    "dotp": (2 * (1 << 22), 2 * (1 << 22) * 2, 4),
}

BARRIER_ALPHA = 1e-6       # per-hop latency of the sync tree


def step_time(flops, bytes_, reduce_bytes, n_chips, with_barrier=True):
    compute = flops / hw.PEAK_FLOPS_BF16
    memory = bytes_ / hw.HBM_BW
    t = max(compute, memory)
    if with_barrier and n_chips > 1:
        # final synchronization barrier: tree latency + reduce payload
        t += 2 * math.log2(n_chips) * BARRIER_ALPHA
        if reduce_bytes:
            # two-stage reduction over the 2-D mesh (not a single big ring)
            a = 2 ** (int(math.log2(n_chips)) // 2)
            b = n_chips // a
            topo = hw.v5e_topology((a, b), ("data", "model"))
            cm = CollectiveModel(topo)
            t += cm.all_reduce(reduce_bytes, "data").seconds
            t += cm.all_reduce(reduce_bytes / a, "model").seconds
    return t


def main() -> list[str]:
    lines = []
    for name, (flops, bytes_, red) in KERNELS.items():
        t1 = step_time(flops, bytes_, red, 1, with_barrier=False)
        for n in (4, 16, 64, 256):
            tb = step_time(flops, bytes_, red, n, with_barrier=True)
            tn = step_time(flops, bytes_, red, n, with_barrier=False)
            # weak scaling: ideal speedup = n
            sp_b = n * t1 / tb
            sp_n = n * t1 / tn
            if n == 256:
                lines.append(
                    f"fig13/{name}@256,0,"
                    f"speedup_frac_with_barrier={sp_b / n:.3f};"
                    f"without={sp_n / n:.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
