"""CI perf gate — exit-code checks over a BENCH_table1.json record.

Replaces the old grep-a-summary-line CI steps with structured checks, and
enforces the autotuner's contract: a *timed* tune is never slower than the
hand-picked default it raced (MemPool's "measured, not modeled" discipline
— the default is a race lane, so losing to it means the tuner regressed).

Checks (each prints one `gate ok:`/`gate FAIL:` line; any FAIL exits 1):

  tuned   every `table1_tuned/*` row satisfies
          us_per_call <= default_us * (1 + --tol)
  require comma-separated section presence: `tuned` (>=1 tuned row),
          `fused` (>=1 `table1_fused/*` row with both timings),
          `decode` (K1 + K16 rows, positive tok/s),
          `serve`  (continuous + static rows, positive tok/s),
          `classes` (per-class SLO rows: latency/throughput/best_effort +
          the serve/slo roll-up, with the scripted contention actually
          exercised — >=1 preemption, >=1 shed, 0 latency deadline misses)
          `paged`  (serve/paged_kv + serve/prefix_reuse rows: positive
          tok/s, prefix reuse actually skipping prefill, warm TTFT
          faster than cold, and capacity_x strictly > 1 — the paged
          layout's equal-memory concurrency claim)
          `recovery` (serve/recovery row: finite positive MTTR, the
          crash drill recovered exactly-once bit-identical, the
          injected bit-flip was detected and repaired with no NaN
          reaching any sharer, and the fault-free journal+snapshot
          overhead stays under --recovery-tol percent)
          `groups` (serve/groups_scaling row: positive aggregate tok/s
          at 1/2/4 serving groups and efficiency >= 0.7 normalized by
          attainable parallelism min(groups, cores); on hosts with >= 4
          cores also monotone tok/s in group count and per-group stall
          within 2x of single-group)
  baseline (optional, vs a committed copy of BENCH_table1.json):
          decode K16 stall_pct must not rise more than --stall-tol
          percentage points; serve continuous occupancy_pct must not drop
          more than --occ-tol percentage points; per-class p99 latency and
          TTFT p99 must not rise more than --class-tol (fraction), and
          per-class deadline misses must not exceed the baseline.

Usage (the CI perf-gate job):

    python benchmarks/run.py --smoke --json /tmp/bench.json   # refreshes
    python benchmarks/check_gate.py --bench BENCH_table1.json \
        --baseline /tmp/baseline_table1.json --require tuned,fused,decode,serve
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REQUIREMENTS = ("tuned", "fused", "decode", "serve", "classes", "paged",
                "recovery", "groups")

CLASS_ROWS = ("serve/class_latency", "serve/class_throughput",
              "serve/class_best_effort")


def _derived(row: dict) -> dict[str, str]:
    d = row.get("derived", "")
    return dict(p.split("=", 1) for p in d.split(";") if "=" in p)


def _rows(record: dict, prefix: str) -> list[dict]:
    return [r for r in record.get("rows", [])
            if r["name"].startswith(prefix)]


def _by_name(rows: list[dict]) -> dict[str, dict]:
    return {r["name"]: r for r in rows}


class Gate:
    def __init__(self):
        self.failures: list[str] = []

    def check(self, ok: bool, label: str, detail: str) -> None:
        if ok:
            print(f"gate ok: {label}: {detail}")
        else:
            print(f"gate FAIL: {label}: {detail}")
            self.failures.append(f"{label}: {detail}")


def check_tuned(gate: Gate, record: dict, tol: float) -> None:
    rows = _rows(record, "table1_tuned/")
    for r in rows:
        kv = _derived(r)
        if "default_us" not in kv:
            gate.check(False, "tuned", f"{r['name']} has no default_us field")
            continue
        tuned_us = float(r["us_per_call"])
        default_us = float(kv["default_us"])
        ok = tuned_us <= default_us * (1.0 + tol)
        gate.check(ok, "tuned",
                   f"{r['name']} tuned {tuned_us:.1f}us vs default "
                   f"{default_us:.1f}us (tol {tol:.0%}, "
                   f"source={kv.get('source', '?')})")


def check_require(gate: Gate, record: dict, require: list[str],
                  recovery_tol: float = 15.0) -> None:
    if "tuned" in require:
        n = len(_rows(record, "table1_tuned/"))
        gate.check(n > 0, "require", f"{n} table1_tuned rows")
    if "fused" in require:
        rows = _rows(record, "table1_fused/")
        ok = bool(rows) and all(
            float(r["us_per_call"]) > 0
            and float(_derived(r).get("unfused_us", 0)) > 0 for r in rows)
        gate.check(ok, "require",
                   f"{len(rows)} table1_fused rows with both timings")
    if "decode" in require:
        by = _by_name(record.get("decode", []))
        ok = {"decode/K1", "decode/K16"} <= set(by) and all(
            float(_derived(r).get("tokens_per_s", 0)) > 0
            for r in by.values())
        gate.check(ok, "require",
                   f"decode rows {sorted(by)} with positive tok/s")
    if "serve" in require:
        by = _by_name(record.get("serve_continuous", []))
        need = {"serve/continuous", "serve/static"}
        ok = need <= set(by) and all(
            float(_derived(by[n]).get("tokens_per_s", 0)) > 0 for n in need)
        gate.check(ok, "require",
                   f"serve rows {sorted(set(by) & need)} with positive tok/s")
    if "classes" in require:
        by = _by_name(record.get("serve_continuous", []))
        missing = [n for n in CLASS_ROWS + ("serve/slo",) if n not in by]
        gate.check(not missing, "classes", f"SLO rows present "
                   f"(missing: {missing or 'none'})")
        if not missing:
            slo = _derived(by["serve/slo"])
            gate.check(int(slo.get("preemptions", 0)) >= 1, "classes",
                       f"preemption exercised "
                       f"({slo.get('preemptions')} preemptions)")
            gate.check(int(slo.get("shed", 0)) >= 1, "classes",
                       f"shedding exercised ({slo.get('shed')} shed)")
            lat = _derived(by["serve/class_latency"])
            gate.check(int(lat.get("deadline_miss", 1)) == 0, "classes",
                       f"latency class deadline misses: "
                       f"{lat.get('deadline_miss')}")
    if "paged" in require:
        by = _by_name(record.get("serve_continuous", []))
        missing = [n for n in ("serve/paged_kv", "serve/prefix_reuse")
                   if n not in by]
        gate.check(not missing, "paged",
                   f"paged rows present (missing: {missing or 'none'})")
        if not missing:
            kv = _derived(by["serve/paged_kv"])
            gate.check(float(kv.get("tokens_per_s", 0)) > 0, "paged",
                       f"paged tok/s {kv.get('tokens_per_s')}")
            gate.check(float(kv.get("capacity_x", 0)) > 1.0, "paged",
                       f"capacity_x {kv.get('capacity_x')} > 1 at equal "
                       f"memory")
            pre = _derived(by["serve/prefix_reuse"])
            gate.check(int(pre.get("prefill_skipped", 0)) > 0, "paged",
                       f"prefill skipped "
                       f"{pre.get('prefill_skipped')} tokens")
            gate.check(float(pre.get("ttft_speedup_x", 0)) > 1.0, "paged",
                       f"warm-vs-cold TTFT speedup "
                       f"{pre.get('ttft_speedup_x')}x")
    if "recovery" in require:
        by = _by_name(record.get("serve_continuous", []))
        gate.check("serve/recovery" in by, "recovery",
                   "serve/recovery row present")
        if "serve/recovery" in by:
            rec = _derived(by["serve/recovery"])
            mttr = float(rec.get("mttr_ms", "nan"))
            gate.check(mttr == mttr and 0.0 < mttr, "recovery",
                       f"finite MTTR ({rec.get('mttr_ms')}ms: journal "
                       f"replay + snapshot load + re-prefill)")
            gate.check(int(rec.get("bit_identical", 0)) == 1, "recovery",
                       "crash-restart outputs bit-identical to fault-free")
            gate.check(int(rec.get("exactly_once", 0)) == 1, "recovery",
                       "no token delivered twice across the crash")
            gate.check(int(rec.get("violations", 0)) >= 1, "recovery",
                       f"bit-flip detected ({rec.get('violations')} "
                       f"checksum violations)")
            gate.check(int(rec.get("repairs", 0)) >= 1, "recovery",
                       f"page repaired by recompute "
                       f"({rec.get('repairs')} repairs)")
            gate.check(int(rec.get("nan_escapes", 1)) == 0, "recovery",
                       f"no NaN escaped to a sharer "
                       f"({rec.get('nan_escapes')} escapes)")
            ov = float(rec.get("overhead_pct", "inf"))
            gate.check(ov <= recovery_tol, "recovery",
                       f"durable overhead {ov:.1f}% <= {recovery_tol:.0f}% "
                       f"(measured tax ~5%; tol absorbs shared-runner "
                       f"fsync jitter)")
    if "groups" in require:
        by = _by_name(record.get("serve_continuous", []))
        gate.check("serve/groups_scaling" in by, "groups",
                   "serve/groups_scaling row present")
        if "serve/groups_scaling" in by:
            gr = _derived(by["serve/groups_scaling"])
            tps = {g: float(gr.get(f"tps{g}", 0)) for g in (1, 2, 4)}
            cores = int(gr.get("cores", 1))
            gate.check(all(v > 0 for v in tps.values()), "groups",
                       f"positive aggregate tok/s at 1/2/4 groups "
                       f"({tps[1]:.0f}/{tps[2]:.0f}/{tps[4]:.0f})")
            eff4 = float(gr.get("eff4", 0))
            gate.check(eff4 >= 0.7, "groups",
                       f"scaling efficiency at 4 groups {eff4:.2f} >= 0.70 "
                       f"(normalized by min(groups, cores={cores}))")
            if cores >= 4:
                # real parallel hardware: demand monotone aggregate
                # throughput and a bounded per-group stall blow-up
                gate.check(tps[1] <= tps[2] <= tps[4], "groups",
                           f"tok/s monotone in group count "
                           f"({tps[1]:.0f} <= {tps[2]:.0f} <= {tps[4]:.0f})")
                s1 = max(float(gr.get("stall1", 0)), 1e-9)
                s4 = float(gr.get("stall4_max", "inf"))
                gate.check(s4 <= 2.0 * max(s1, 5.0), "groups",
                           f"per-group stall at 4 groups {s4:.1f}% within "
                           f"2x of single-group {s1:.1f}%")
            else:
                # serialized host: G computes time-share the core, so
                # ideal aggregate tok/s is flat — bound the sharding
                # overhead instead of demanding impossible speedup
                gate.check(tps[4] >= 0.7 * tps[1], "groups",
                           f"sharding overhead bounded on {cores}-core "
                           f"host ({tps[4]:.0f} vs {tps[1]:.0f} tok/s "
                           f"single-group)")


def check_baseline(gate: Gate, record: dict, baseline: dict,
                   stall_tol: float, occ_tol: float,
                   class_tol: float) -> None:
    new_dec = _by_name(record.get("decode", []))
    old_dec = _by_name(baseline.get("decode", []))
    if "decode/K16" in new_dec and "decode/K16" in old_dec:
        new_stall = float(_derived(new_dec["decode/K16"])["stall_pct"])
        old_stall = float(_derived(old_dec["decode/K16"])["stall_pct"])
        gate.check(new_stall <= old_stall + stall_tol, "baseline",
                   f"K16 stall {new_stall:.1f}% vs baseline "
                   f"{old_stall:.1f}% (+{stall_tol:.1f}pt tol)")
    new_srv = _by_name(record.get("serve_continuous", []))
    old_srv = _by_name(baseline.get("serve_continuous", []))
    if "serve/continuous" in new_srv and "serve/continuous" in old_srv:
        new_occ = float(_derived(new_srv["serve/continuous"])["occupancy_pct"])
        old_occ = float(_derived(old_srv["serve/continuous"])["occupancy_pct"])
        gate.check(new_occ >= old_occ - occ_tol, "baseline",
                   f"serve occupancy {new_occ:.1f}% vs baseline "
                   f"{old_occ:.1f}% (-{occ_tol:.1f}pt tol)")
    for name in CLASS_ROWS:
        if name not in new_srv or name not in old_srv:
            continue
        new_kv, old_kv = _derived(new_srv[name]), _derived(old_srv[name])
        klass = name.removeprefix("serve/class_")
        for field in ("p99_ms", "ttft_p99_ms"):
            new_v, old_v = float(new_kv[field]), float(old_kv[field])
            gate.check(new_v <= old_v * (1.0 + class_tol), "baseline",
                       f"{klass} {field} {new_v:.1f} vs baseline "
                       f"{old_v:.1f} (tol {class_tol:.0%})")
        new_m, old_m = (int(new_kv.get("deadline_miss", 0)),
                        int(old_kv.get("deadline_miss", 0)))
        gate.check(new_m <= old_m, "baseline",
                   f"{klass} deadline misses {new_m} vs baseline {old_m}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", required=True,
                    help="fresh BENCH_table1.json to gate on")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_table1.json to diff against")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="tuned-vs-default timer-noise tolerance (fraction)")
    ap.add_argument("--stall-tol", type=float, default=2.0,
                    help="decode stall_pct regression tolerance (abs points)")
    ap.add_argument("--occ-tol", type=float, default=10.0,
                    help="serve occupancy regression tolerance (abs points)")
    ap.add_argument("--class-tol", type=float, default=1.0,
                    help="per-class p99/TTFT regression tolerance (fraction;"
                         " wall-clock percentiles are CI-noisy, so default"
                         " allows 2x before failing)")
    ap.add_argument("--recovery-tol", type=float, default=15.0,
                    help="durable-serving overhead ceiling (percent of "
                         "fault-free tokens/s; the measured journal+snapshot "
                         "tax is ~5%%, headroom absorbs runner fsync jitter "
                         "— a real regression like an un-overlapped snapshot "
                         "capture reads 30%%+)")
    ap.add_argument("--require", default="tuned",
                    help=f"comma-separated presence checks {REQUIREMENTS}")
    args = ap.parse_args(argv)

    record = json.loads(Path(args.bench).read_text())
    require = [r for r in args.require.split(",") if r]
    unknown = set(require) - set(REQUIREMENTS)
    if unknown:
        ap.error(f"unknown --require item(s) {sorted(unknown)}; "
                 f"available: {REQUIREMENTS}")

    gate = Gate()
    check_tuned(gate, record, args.tol)
    check_require(gate, record, require, args.recovery_tol)
    if args.baseline:
        baseline = json.loads(Path(args.baseline).read_text())
        check_baseline(gate, record, baseline, args.stall_tol, args.occ_tol,
                       args.class_tol)

    if gate.failures:
        print(f"perf gate: {len(gate.failures)} FAILURE(S)", file=sys.stderr)
        return 1
    print("perf gate: all checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
