"""Paper Fig. 4 — topology throughput/latency vs injected load.

Sweeps the Top_1 / Top_4 / Top_H models (core/interconnect.py) over load and
reports the saturation points; the paper's numbers: Top_1 congests near
0.10 req/core/cycle, Top_4 ~0.37, Top_H ~0.40, with Top_H average latency
~6 cycles at 0.35 load.
"""

from __future__ import annotations

import numpy as np

from repro.core.interconnect import TOP_1, TOP_4, TOP_H, TopologyModel


def sweep(model: TopologyModel, loads) -> list[tuple[float, float, float]]:
    return [(l, model.accepted_load(l), model.avg_latency(l)) for l in loads]


def saturation_point(model: TopologyModel) -> float:
    loads = np.linspace(0.01, 0.8, 200)
    for l in loads:
        if model.accepted_load(l) < 0.98 * l:
            return float(l)
    return float(loads[-1])


def main() -> list[str]:
    lines = []
    for spec in (TOP_1, TOP_4, TOP_H):
        m = TopologyModel(spec)
        sat = saturation_point(m)
        lat35 = m.avg_latency(0.35)
        lines.append(f"fig4/{spec.name},0,"
                     f"saturation={sat:.3f};latency@0.35={lat35:.2f}cyc")
    # the paper's qualitative conclusion: Top_H wins
    th = saturation_point(TopologyModel(TOP_H))
    t1 = saturation_point(TopologyModel(TOP_1))
    lines.append(f"fig4/conclusion,0,TopH/Top1_throughput={th / t1:.2f}x")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
