"""Paper Table 1 — the kernel suite (matmul / 2dconv / dct / axpy / dotp).

Measures wall time per call (interpret mode on CPU — functional numbers) and
derives the quantities the paper reports per kernel: operation count,
arithmetic intensity, and the projected TPU-v5e roofline utilization
(min(peak_flops, intensity * HBM_bw) — the hardware-honest analogue of the
paper's OP/cycle column; MemPool's 32-bit MACs count as 2 OPs there).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as hw
from repro.kernels import ops


def timeit(fn, *args, reps: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def rows() -> list[dict]:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    out = []

    # matmul 256x256 (paper size), bf16-on-TPU modeled as f32 here
    n = 256
    a = jax.random.normal(ks[0], (n, n), jnp.float32)
    b = jax.random.normal(ks[1], (n, n), jnp.float32)
    flops = 2 * n ** 3
    bytes_ = 3 * n * n * 4
    out.append(_row("matmul", f"{n}x{n}", lambda: ops.matmul(a, b, bm=128,
                                                             bn=128, bk=128),
                    flops, bytes_))

    # 2dconv 96x1024 with 3x3 kernel (paper size)
    img = jax.random.normal(ks[2], (96, 1024), jnp.float32)
    w = jax.random.normal(ks[3], (3, 3), jnp.float32)
    flops = 2 * 9 * 96 * 1024
    bytes_ = 2 * 96 * 1024 * 4
    out.append(_row("2dconv", "96x1024", lambda: ops.conv2d_3x3(img, w),
                    flops, bytes_))

    # dct 192x1024 image = 24576 8x8 blocks (paper size)
    blocks = jax.random.normal(ks[4], (192 * 1024 // 64, 8, 8), jnp.float32)
    nblk = blocks.shape[0]
    flops = nblk * 2 * 2 * 8 ** 3          # two 8x8x8 matmuls per block
    bytes_ = 2 * nblk * 64 * 4
    out.append(_row("dct", "192x1024", lambda: ops.dct8x8(blocks), flops,
                    bytes_))

    # axpy / dotp over 98304 elements (paper size)
    m = 98304 // 128
    x = jax.random.normal(ks[5], (m, 128), jnp.float32)
    y = jax.random.normal(ks[6], (m, 128), jnp.float32)
    out.append(_row("axpy", "98304", lambda: ops.axpy(2.0, x, y),
                    2 * 98304, 3 * 98304 * 4))
    out.append(_row("dotp", "98304", lambda: ops.dotp(x, y),
                    2 * 98304, 2 * 98304 * 4))
    return out


def _row(name, size, fn, flops, bytes_) -> dict:
    us = timeit(lambda: fn()) * 1e6
    intensity = flops / bytes_
    roof = min(hw.PEAK_FLOPS_BF16, intensity * hw.HBM_BW)
    # paper comparison: measured OP/cycle fraction of MemPool's 512 peak
    paper_frac = {"matmul": 285 / 512, "2dconv": 336 / 512, "dct": 168 / 512,
                  "axpy": 90 / 512, "dotp": 92 / 512}[name]
    return {"name": f"table1/{name}", "size": size, "us_per_call": us,
            "flops": flops, "intensity": intensity,
            "tpu_roofline_flops": roof,
            "tpu_roofline_frac": roof / hw.PEAK_FLOPS_BF16,
            "mempool_frac": paper_frac}


def main() -> list[str]:
    lines = []
    for r in rows():
        lines.append(
            f"{r['name']},{r['us_per_call']:.1f},"
            f"intensity={r['intensity']:.2f};roof_frac="
            f"{r['tpu_roofline_frac']:.3f};mempool_frac={r['mempool_frac']:.3f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
