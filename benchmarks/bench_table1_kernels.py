"""Paper Table 1 — the kernel suite (matmul / 2dconv / dct / axpy / dotp).

Measures wall time per call (interpret mode on CPU — functional numbers) and
derives the quantities the paper reports per kernel: operation count,
arithmetic intensity, and the projected TPU-v5e roofline utilization
(min(peak_flops, intensity * HBM_bw) — the hardware-honest analogue of the
paper's OP/cycle column; MemPool's 32-bit MACs count as 2 OPs there).

Second section: tuned-vs-default through the tile-pipeline layer — for every
registered kernel, the autotuner's blocking (kernels/pipeline.autotune,
scored on the roofline + interconnect cost models) against the hand-picked
defaults, with both measured wall time and modeled seconds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as hw
from repro.kernels import fused, ops, pipeline as pp


def timeit(fn, *args, reps: int = 3) -> float:
    """Median wall seconds per call — the same warmup + median-of-repeats
    loop the autotuner races candidates with (kernels/pipeline.median_time),
    so bench rows and tune records are comparable numbers."""
    return pp.median_time(lambda: fn(*args), reps=reps, warmup=1)


def rows(smoke: bool = False) -> list[dict]:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    out = []
    reps = 1 if smoke else 3

    # matmul 256x256 (paper size), bf16-on-TPU modeled as f32 here
    n = 128 if smoke else 256
    a = jax.random.normal(ks[0], (n, n), jnp.float32)
    b = jax.random.normal(ks[1], (n, n), jnp.float32)
    flops = 2 * n ** 3
    bytes_ = 3 * n * n * 4
    out.append(_row("matmul", f"{n}x{n}", lambda: ops.matmul(a, b, bm=128,
                                                             bn=128, bk=128),
                    flops, bytes_, reps=reps))

    # 2dconv 96x1024 with 3x3 kernel (paper size)
    H, W = (32, 256) if smoke else (96, 1024)
    img = jax.random.normal(ks[2], (H, W), jnp.float32)
    w = jax.random.normal(ks[3], (3, 3), jnp.float32)
    flops = 2 * 9 * H * W
    bytes_ = 2 * H * W * 4
    out.append(_row("2dconv", f"{H}x{W}", lambda: ops.conv2d_3x3(img, w),
                    flops, bytes_, reps=reps))

    # dct 192x1024 image = 24576 8x8 blocks (paper size)
    nblk = 512 if smoke else 192 * 1024 // 64
    blocks = jax.random.normal(ks[4], (nblk, 8, 8), jnp.float32)
    flops = nblk * 2 * 2 * 8 ** 3          # two 8x8x8 matmuls per block
    bytes_ = 2 * nblk * 64 * 4
    out.append(_row("dct", f"{nblk}blk", lambda: ops.dct8x8(blocks), flops,
                    bytes_, reps=reps))

    # axpy / dotp over 98304 elements (paper size)
    total = 8192 if smoke else 98304
    m = total // 128
    x = jax.random.normal(ks[5], (m, 128), jnp.float32)
    y = jax.random.normal(ks[6], (m, 128), jnp.float32)
    out.append(_row("axpy", str(total), lambda: ops.axpy(2.0, x, y),
                    2 * total, 3 * total * 4, reps=reps))
    out.append(_row("dotp", str(total), lambda: ops.dotp(x, y),
                    2 * total, 2 * total * 4, reps=reps))
    return out


def _row(name, size, fn, flops, bytes_, reps: int = 3) -> dict:
    us = timeit(lambda: fn(), reps=reps) * 1e6
    intensity = flops / bytes_
    roof = min(hw.PEAK_FLOPS_BF16, intensity * hw.HBM_BW)
    # paper comparison: measured OP/cycle fraction of MemPool's 512 peak
    paper_frac = {"matmul": 285 / 512, "2dconv": 336 / 512, "dct": 168 / 512,
                  "axpy": 90 / 512, "dotp": 92 / 512}[name]
    return {"name": f"table1/{name}", "size": size, "us_per_call": us,
            "flops": flops, "intensity": intensity,
            "tpu_roofline_flops": roof,
            "tpu_roofline_frac": roof / hw.PEAK_FLOPS_BF16,
            "mempool_frac": paper_frac}


# ----------------------------------------------------------------------------
# tuned vs default through the pipeline layer
# ----------------------------------------------------------------------------

def _tune_operands(smoke: bool) -> dict[str, tuple]:
    ks = jax.random.split(jax.random.PRNGKey(1), 16)
    if smoke:
        mn, mm, s = (64, 128), (128, 128, 128), 128
        hwc, nblk, rms = (32, 256), 256, (64, 128)
    else:
        mn, mm, s = (768, 128), (512, 512, 512), 512
        hwc, nblk, rms = (96, 1024), 3072, (512, 512)
    return {
        "axpy": (2.0, jax.random.normal(ks[0], mn, jnp.float32),
                 jax.random.normal(ks[1], mn, jnp.float32)),
        "dotp": (jax.random.normal(ks[2], mn, jnp.float32),
                 jax.random.normal(ks[3], mn, jnp.float32)),
        "matmul": (jax.random.normal(ks[4], (mm[0], mm[2]), jnp.float32),
                   jax.random.normal(ks[5], (mm[2], mm[1]), jnp.float32)),
        "conv2d": (jax.random.normal(ks[6], hwc, jnp.float32),
                   jax.random.normal(ks[7], (3, 3), jnp.float32)),
        "dct8x8": (jax.random.normal(ks[8], (nblk, 8, 8), jnp.float32),),
        "rmsnorm": (jax.random.normal(ks[9], rms, jnp.float32),
                    jax.random.normal(ks[10], rms[-1:], jnp.float32) * 0.1),
        "flash_attention": (
            jax.random.normal(ks[11], (1, 4, s, 64), jnp.float32),
            jax.random.normal(ks[12], (1, 2, s, 64), jnp.float32),
            jax.random.normal(ks[13], (1, 2, s, 64), jnp.float32)),
    }


def tuned_rows(smoke: bool = False) -> list[dict]:
    reps = 1 if smoke else 3
    out = []
    for name, operands in _tune_operands(smoke).items():
        shapes = ops.kernel_shapes(name, *operands)
        # registry-first: a warm TuneDB (or an earlier row this process)
        # satisfies this without re-racing — a second benchmark run against
        # the same DB performs zero candidate races
        rec = pp.tuned_record(name, shapes)
        if rec.timed:
            # both lanes were timed in the race itself, by the same timer,
            # so tuned <= default holds by construction
            us_tuned, us_default = rec.measured_us, rec.default_us
        else:
            # modeled/frozen pick (or db record from an untimed run): time
            # both lanes here through the wrappers
            wrapper = ops.wrapper_for(name)
            us_default = timeit(
                lambda: wrapper(*operands, **dict(rec.default_blocks)),
                reps=reps) * 1e6
            us_tuned = timeit(lambda: ops.tuned_call(name, *operands),
                              reps=reps) * 1e6
        cost = pp.score(pp.KERNELS[name].traffic(shapes, dict(rec.blocks), 4))
        out.append({
            "name": f"table1_tuned/{name}",
            "blocks": dict(rec.blocks),
            "default_blocks": dict(rec.default_blocks),
            "us_default": us_default,
            "us_tuned": us_tuned,
            "modeled_default_s": rec.default_modeled_seconds,
            "modeled_tuned_s": rec.modeled_seconds,
            "measured_speedup": rec.measured_speedup,
            "source": rec.source,
            "p_local": cost.p_local,
        })
    return out


# ----------------------------------------------------------------------------
# fused vs unfused composition (kernels/fused.py)
# ----------------------------------------------------------------------------

def _fused_cases(smoke: bool) -> dict[str, tuple]:
    """(fused_fn, unfused_fn, fused_kernel_name, shapes) per fused kernel."""
    ks = jax.random.split(jax.random.PRNGKey(2), 8)
    if smoke:
        m, k, n, s, hd, h, kv, dm = 128, 64, 128, 128, 32, 4, 2, 64
    else:
        m, k, n, s, hd, h, kv, dm = 512, 512, 512, 512, 64, 4, 2, 256
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    sc = jax.random.normal(ks[1], (k,), jnp.float32) * 0.1
    w = jax.random.normal(ks[2], (k, n), jnp.float32)
    bias = jax.random.normal(ks[3], (n,), jnp.float32)
    res = jax.random.normal(ks[4], (m, n), jnp.float32)
    q = jax.random.normal(ks[5], (1, h, s, hd), jnp.float32)
    kk = jax.random.normal(ks[6], (1, kv, s, hd), jnp.float32)
    v = jax.random.normal(ks[7], (1, kv, s, hd), jnp.float32)
    wo = jax.random.normal(ks[0], (h, hd, dm), jnp.float32) * 0.1
    return {
        "rmsnorm_matmul": (
            lambda: ops.rmsnorm_matmul(x, sc, w),
            lambda: ops.matmul(ops.rmsnorm(x, sc), w),
            {"m": m, "k": k, "n": n}),
        "matmul_bias_act": (
            lambda: ops.matmul_bias_act(x, w, bias, act="gelu"),
            lambda: jax.nn.gelu(ops.matmul(x, w) + bias),
            {"m": m, "k": k, "n": n}),
        "matmul_residual_add": (
            lambda: ops.matmul_residual_add(x, w, res),
            lambda: ops.matmul(x, w) + res,
            {"m": m, "k": k, "n": n}),
        "flash_attention_proj": (
            lambda: ops.flash_attention_proj(q, kk, v, wo),
            lambda: jnp.einsum(
                "bhsk,hkd->bsd",
                ops.flash_attention(q, kk, v), wo),
            {"b": 1, "h": h, "kv": kv, "s": s, "hd": hd, "dm": dm}),
    }


def fused_rows(smoke: bool = False) -> list[dict]:
    reps = 1 if smoke else 3
    out = []
    for name, (fused_fn, unfused_fn, shapes) in _fused_cases(smoke).items():
        t_fused = timeit(fused_fn, reps=reps)
        t_unfused = timeit(unfused_fn, reps=reps)
        model = fused.fused_vs_unfused(name, shapes)
        # race the fusion against its own unfused composition (registry-first
        # — warm DB runs don't re-race) and report which route the tuner
        # will dispatch for this shape
        rec = pp.tuned_record(name, shapes)
        out.append({
            "name": f"table1_fused/{name}",
            "us_fused": t_fused * 1e6,
            "us_unfused": t_unfused * 1e6,
            "fused_bytes": model["fused_bytes"],
            "unfused_bytes": model["unfused_bytes"],
            "bytes_reduction": model["reduction"],
            "route": rec.route,
        })
    return out


def main(smoke: bool = False) -> list[str]:
    lines = []
    for r in rows(smoke):
        lines.append(
            f"{r['name']},{r['us_per_call']:.1f},"
            f"intensity={r['intensity']:.2f};roof_frac="
            f"{r['tpu_roofline_frac']:.3f};mempool_frac={r['mempool_frac']:.3f}")
    for r in tuned_rows(smoke):
        blocks = "/".join(f"{k}={v}" for k, v in sorted(r["blocks"].items()))
        lines.append(
            f"{r['name']},{r['us_tuned']:.1f},"
            f"default_us={r['us_default']:.1f};blocks={blocks};"
            f"measured_speedup={r['measured_speedup']:.2f};"
            f"source={r['source']};p_local={r['p_local']:.3f}")
    for r in fused_rows(smoke):
        lines.append(
            f"{r['name']},{r['us_fused']:.1f},"
            f"unfused_us={r['us_unfused']:.1f};"
            f"fused_GB={r['fused_bytes'] / 1e9:.4f};"
            f"unfused_GB={r['unfused_bytes'] / 1e9:.4f};"
            f"bytes_reduction={r['bytes_reduction']:.2f};"
            f"route={r['route']}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
