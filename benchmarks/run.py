"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows, exactly one section per paper
artifact (Table 1, Fig. 4, 5, 13, 14, 15, 16). Modules degrade gracefully
when optional inputs (dry-run results) are absent.
"""

from __future__ import annotations

import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import (bench_fig4_interconnect, bench_fig5_hybrid,  # noqa: E402
                        bench_fig13_scaling, bench_fig14_breakdown,
                        bench_fig15_double_buffer, bench_fig16_energy,
                        bench_table1_kernels)

MODULES = [
    ("table1", bench_table1_kernels),
    ("fig4", bench_fig4_interconnect),
    ("fig5", bench_fig5_hybrid),
    ("fig13", bench_fig13_scaling),
    ("fig14", bench_fig14_breakdown),
    ("fig15", bench_fig15_double_buffer),
    ("fig16", bench_fig16_energy),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, mod in MODULES:
        t0 = time.perf_counter()
        try:
            for line in mod.main():
                print(line)
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
