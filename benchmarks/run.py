"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows, exactly one section per paper
artifact (Table 1, Fig. 4, 5, 13, 14, 15, 16). Modules degrade gracefully
when optional inputs (dry-run results) are absent.

Flags:
  --smoke       tiny shapes / model-only paths so every bench finishes in
                seconds — the CI smoke lane
  --json PATH   also write the rows as structured JSON (uploaded as a CI
                artifact)
  --only NAMES  comma-separated subset of sections
  --repeat N    run each section N times and report the per-row median
                us_per_call (derived fields from the first run)

Whenever the table1 section runs, its rows are also persisted to
`BENCH_table1.json` at the repo root — the perf-trajectory record the CI
smoke job refreshes on every run — and a `fused-vs-unfused:` summary line
is printed for the fused kernel path.
"""

from __future__ import annotations

import argparse
import inspect
import json
import statistics
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import (bench_fig4_interconnect, bench_fig5_hybrid,  # noqa: E402
                        bench_fig13_scaling, bench_fig14_breakdown,
                        bench_fig15_double_buffer, bench_fig16_energy,
                        bench_table1_kernels)

MODULES = [
    ("table1", bench_table1_kernels),
    ("fig4", bench_fig4_interconnect),
    ("fig5", bench_fig5_hybrid),
    ("fig13", bench_fig13_scaling),
    ("fig14", bench_fig14_breakdown),
    ("fig15", bench_fig15_double_buffer),
    ("fig16", bench_fig16_energy),
]


def _call_main(mod, smoke: bool) -> list[str]:
    if "smoke" in inspect.signature(mod.main).parameters:
        return mod.main(smoke=smoke)
    return mod.main()


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived}


def _median_lines(runs: list[list[str]]) -> list[str]:
    """Per-row median us_per_call across repeats (first run's derived)."""
    if len(runs) == 1:
        return runs[0]
    by_name: dict[str, list[float]] = {}
    for run in runs:
        for line in run:
            r = _parse_row(line)
            if r["us_per_call"] is not None:
                by_name.setdefault(r["name"], []).append(r["us_per_call"])
    out = []
    for line in runs[0]:
        r = _parse_row(line)
        if r["us_per_call"] is None or r["name"] not in by_name:
            out.append(line)
            continue
        med = statistics.median(by_name[r["name"]])
        out.append(f"{r['name']},{med:.1f},{r['derived']}")
    return out


def _fused_comparison_line(rows: list[dict]) -> str | None:
    """One-line fused-vs-unfused summary from the table1_fused rows."""
    parts = []
    for r in rows:
        if not r["name"].startswith("table1_fused/"):
            continue
        kv = dict(p.split("=", 1) for p in r["derived"].split(";"))
        parts.append(
            f"{r['name'].removeprefix('table1_fused/')}"
            f" {r['us_per_call']:.0f}us (unfused {float(kv['unfused_us']):.0f}us,"
            f" bytes x{kv['bytes_reduction']})")
    if not parts:
        return None
    return "# fused-vs-unfused: " + " | ".join(parts)


def _persist_table1(results: dict, repeat: int) -> Path | None:
    section = results["sections"].get("table1")
    if not section or section["status"] != "ok":
        return None
    path = Path(__file__).resolve().parents[1] / "BENCH_table1.json"
    path.write_text(json.dumps(
        {"smoke": results["smoke"], "timestamp": results["timestamp"],
         "repeat": repeat, "rows": section["rows"]}, indent=2))
    return path


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; every section in seconds")
    ap.add_argument("--json", default=None,
                    help="write structured results to this path")
    ap.add_argument("--only", default=None,
                    help="comma-separated section subset (e.g. table1,fig4)")
    ap.add_argument("--repeat", type=int, default=1,
                    help="median-of-N timing: run each section N times")
    args = ap.parse_args(argv)
    if args.repeat < 1:
        ap.error("--repeat must be >= 1")

    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - {name for name, _ in MODULES}
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}; "
                     f"available: {[n for n, _ in MODULES]}")
    print("name,us_per_call,derived")
    failed = []
    results: dict = {"smoke": args.smoke, "timestamp": time.time(),
                     "sections": {}}
    for name, mod in MODULES:
        if only is not None and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            lines = _median_lines(
                [_call_main(mod, args.smoke) for _ in range(args.repeat)])
            for line in lines:
                print(line)
            results["sections"][name] = {
                "status": "ok",
                "seconds": time.perf_counter() - t0,
                "rows": [_parse_row(l) for l in lines],
            }
        except Exception as e:
            failed.append(name)
            traceback.print_exc()
            results["sections"][name] = {
                "status": "error",
                "seconds": time.perf_counter() - t0,
                "error": f"{type(e).__name__}: {e}",
            }
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    table1 = results["sections"].get("table1")
    if table1 and table1["status"] == "ok":
        cmp_line = _fused_comparison_line(table1["rows"])
        if cmp_line:
            print(cmp_line)
        persisted = _persist_table1(results, args.repeat)
        if persisted:
            print(f"# wrote {persisted}", file=sys.stderr)
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(results, indent=2))
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
