"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows, exactly one section per paper
artifact (Table 1, Fig. 4, 5, 13, 14, 15, 16). Modules degrade gracefully
when optional inputs (dry-run results) are absent.

A thin wrapper over the Cluster façade: the CLI builds a kernel-only
`repro.cluster.Cluster`, scopes the requested `KernelPolicy` on it, and
compiles a `BenchProgram` — every section runs under that policy, and the
emitted JSON records the active policy (mode, overrides, and the
tune-record hit/miss counters) so every row is attributable to a policy.

Flags:
  --smoke       tiny shapes / model-only paths so every bench finishes in
                seconds — the CI smoke lane
  --json PATH   also write the rows as structured JSON (uploaded as a CI
                artifact)
  --only NAMES  comma-separated subset of sections
  --repeat N    run each section N times and report the per-row median
                us_per_call (derived fields from the first run)
  --policy MODE kernel policy mode the sweep runs under (default "tuned")
  --tune-db P   persistent TuneDB path: timed tune races warm-start from it
                and write back to it (default: the REPRO_TUNE_DB env var;
                unset means no persistence). A warm DB makes the second
                run race-free — the `# tune:` summary line shows
                hits/misses/races/warm-start counts either way.

Whenever the table1 section runs, its rows are also persisted to
`BENCH_table1.json` at the repo root — the perf-trajectory record the CI
smoke job refreshes on every run — and a `fused-vs-unfused:` summary line
is printed for the fused kernel path. The decode (K=1 vs K=16 engine) and
serve (continuous vs static batching) rows and their `decode-throughput:`
/ `serve-continuous:` summary lines ride along in the same record.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cluster import BenchProgram, Cluster  # noqa: E402
from repro.cluster.policy import MODES  # noqa: E402

from benchmarks import (bench_decode_throughput,  # noqa: E402
                        bench_fig4_interconnect, bench_fig5_hybrid,
                        bench_fig13_scaling, bench_fig14_breakdown,
                        bench_fig15_double_buffer, bench_fig16_energy,
                        bench_serve_continuous, bench_table1_kernels)

MODULES = [
    ("table1", bench_table1_kernels),
    ("decode", bench_decode_throughput),
    ("serve", bench_serve_continuous),
    ("fig4", bench_fig4_interconnect),
    ("fig5", bench_fig5_hybrid),
    ("fig13", bench_fig13_scaling),
    ("fig14", bench_fig14_breakdown),
    ("fig15", bench_fig15_double_buffer),
    ("fig16", bench_fig16_energy),
]


def _fused_comparison_line(rows: list[dict]) -> str | None:
    """One-line fused-vs-unfused summary from the table1_fused rows."""
    parts = []
    for r in rows:
        if not r["name"].startswith("table1_fused/"):
            continue
        kv = dict(p.split("=", 1) for p in r["derived"].split(";"))
        parts.append(
            f"{r['name'].removeprefix('table1_fused/')}"
            f" {r['us_per_call']:.0f}us (unfused {float(kv['unfused_us']):.0f}us,"
            f" bytes x{kv['bytes_reduction']})")
    if not parts:
        return None
    return "# fused-vs-unfused: " + " | ".join(parts)


def _decode_rows(results: dict) -> list[dict]:
    section = results["sections"].get("decode")
    if not section or section["status"] != "ok":
        return []
    return section["rows"]


def _decode_comparison_line(rows: list[dict]) -> str | None:
    """K=1 (per-token loop) vs K=16 (scan-compiled engine) summary."""
    by_k = {}
    for r in rows:
        kv = dict(p.split("=", 1) for p in r["derived"].split(";"))
        by_k[r["name"].removeprefix("decode/")] = (r["us_per_call"], kv)
    if "K1" not in by_k or "K16" not in by_k:
        return None
    (us1, kv1), (us16, kv16) = by_k["K1"], by_k["K16"]
    return (f"# decode-throughput: K16 {float(kv16['tokens_per_s']):.1f} tok/s"
            f" (stall {float(kv16['stall_pct']):.1f}%,"
            f" {kv16['host_syncs']} syncs) vs"
            f" K1 {float(kv1['tokens_per_s']):.1f} tok/s"
            f" (stall {float(kv1['stall_pct']):.1f}%,"
            f" {kv1['host_syncs']} syncs) —"
            f" {us1 / max(us16, 1e-9):.2f}x per-token speedup")


def _serve_rows(results: dict) -> list[dict]:
    section = results["sections"].get("serve")
    if not section or section["status"] != "ok":
        return []
    return section["rows"]


def _serve_comparison_line(rows: list[dict]) -> str | None:
    """Continuous vs static batching summary from the serve section."""
    by_name = {}
    for r in rows:
        kv = dict(p.split("=", 1) for p in r["derived"].split(";"))
        by_name[r["name"].removeprefix("serve/")] = kv
    if "continuous" not in by_name or "static" not in by_name:
        return None
    c, s = by_name["continuous"], by_name["static"]
    tps_c, tps_s = float(c["tokens_per_s"]), float(s["tokens_per_s"])
    occ_c, occ_s = float(c["occupancy_pct"]), float(s["occupancy_pct"])
    return (f"# serve-continuous: {tps_c:.1f} tok/s, occ {occ_c:.1f}% vs"
            f" static {tps_s:.1f} tok/s, occ {occ_s:.1f}% —"
            f" {tps_c / max(tps_s, 1e-9):.2f}x tok/s,"
            f" {occ_c / max(occ_s, 1e-9):.2f}x occupancy;"
            f" p99 {float(c['p99_ms']):.0f}ms vs {float(s['p99_ms']):.0f}ms"
            f" ({c['requests']} reqs, {c['slots']} slots)")


def _persist_table1(results: dict, repeat: int) -> Path | None:
    section = results["sections"].get("table1")
    if not section or section["status"] != "ok":
        return None
    path = Path(__file__).resolve().parents[1] / "BENCH_table1.json"
    record = {"smoke": results["smoke"], "timestamp": results["timestamp"],
              "repeat": repeat, "policy": results["policy"],
              "rows": section["rows"]}
    if "tuning" in results:
        record["tuning"] = results["tuning"]
    decode = _decode_rows(results)
    if decode:
        # the K=1 vs K=16 engine trajectory rides with the kernel table
        record["decode"] = [r for r in decode
                            if r["name"] in ("decode/K1", "decode/K16")]
        line = _decode_comparison_line(decode)
        if line:
            record["decode_summary"] = line.removeprefix(
                "# decode-throughput: ")
    serve = _serve_rows(results)
    if serve:
        # continuous vs static batching rows ride along too
        record["serve_continuous"] = serve
        line = _serve_comparison_line(serve)
        if line:
            record["serve_summary"] = line.removeprefix(
                "# serve-continuous: ")
    path.write_text(json.dumps(record, indent=2))
    return path


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes; every section in seconds")
    ap.add_argument("--json", default=None,
                    help="write structured results to this path")
    ap.add_argument("--only", default=None,
                    help="comma-separated section subset (e.g. table1,fig4)")
    ap.add_argument("--repeat", type=int, default=1,
                    help="median-of-N timing: run each section N times")
    ap.add_argument("--policy", default="tuned", choices=MODES,
                    help="kernel policy mode the sweep runs under")
    ap.add_argument("--tune-db", default=None,
                    help="TuneDB path (default: REPRO_TUNE_DB env)")
    args = ap.parse_args(argv)
    if args.repeat < 1:
        ap.error("--repeat must be >= 1")

    only = tuple(args.only.split(",")) if args.only else ()
    if only:
        unknown = set(only) - {name for name, _ in MODULES}
        if unknown:
            ap.error(f"unknown section(s) {sorted(unknown)}; "
                     f"available: {[n for n, _ in MODULES]}")

    # kernel-only cluster; a tune DB (flag or env) warm-starts KERNEL_TUNES
    cluster = Cluster(policy=args.policy, tune_db=args.tune_db)
    program = cluster.compile(BenchProgram(sections=only, smoke=args.smoke,
                                           repeat=args.repeat))
    print("name,us_per_call,derived")
    results = program.run(MODULES)
    results["timestamp"] = time.time()
    stats = cluster._policy.stats
    tune_line = (f"# tune: hits={stats.get('tune_hits', 0)}"
                 f" misses={stats.get('tune_misses', 0)}"
                 f" races={stats.get('tune_races', 0)}"
                 f" warm={cluster.tune_db_warm}")
    results["tuning"] = {"hits": stats.get("tune_hits", 0),
                         "misses": stats.get("tune_misses", 0),
                         "races": stats.get("tune_races", 0),
                         "warm_started": cluster.tune_db_warm}
    if cluster.tune_db is not None:
        db = cluster.tune_db
        results["tuning"]["tunedb"] = db.describe()
        tune_line += (f" db={db.path} entries={len(db)}"
                      f"{' (frozen)' if db.frozen else ''}")
    print(tune_line)
    failed = results.pop("failed")
    decode_rows = _decode_rows(results)
    if decode_rows:
        dec_line = _decode_comparison_line(decode_rows)
        if dec_line:
            print(dec_line)
    serve_rows = _serve_rows(results)
    if serve_rows:
        srv_line = _serve_comparison_line(serve_rows)
        if srv_line:
            print(srv_line)
    table1 = results["sections"].get("table1")
    if table1 and table1["status"] == "ok":
        cmp_line = _fused_comparison_line(table1["rows"])
        if cmp_line:
            print(cmp_line)
        persisted = _persist_table1(results, args.repeat)
        if persisted:
            print(f"# wrote {persisted}", file=sys.stderr)
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(results, indent=2))
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
