"""Decode throughput vs host-sync cadence — the execution-stall figure.

The paper's §5 result is <2% execution stalls at 256 cores: independent
instruction paths mean cores never wait on a shared frontend. Our frontend
is the Python host loop; this bench sweeps the engine's K knob (decode
steps per host sync — `ServeProgram(chunk=K)`) and reports tokens/s plus
the StallClock's `stall_pct` (host-side dispatch gap as a fraction of wall
time). K=1 is the per-token loop (one dispatch + one sync per token);
K>1 is the scan-compiled engine (runtime/engine.py). Expect tokens/s up
and stall_pct + host_syncs down as K grows, saturating once the host gap
is fully buried — the software analogue of Fig. 15's steady-state rounds.

Row format: decode/K{K},us_per_token,tokens_per_s=..;stall_pct=..;...
"""

from __future__ import annotations

ARCH = "xlstm-125m-smoke"


def run(ks: tuple[int, ...], batch: int, max_seq: int, max_new: int) -> list[dict]:
    from repro.cluster import Cluster, ServeProgram

    cluster = Cluster(ARCH)
    params = None
    rows = []
    for k in ks:
        program = cluster.compile(ServeProgram(
            batch=batch, max_seq=max_seq, max_new=max_new, chunk=k))
        if params is None:
            params = program.init_params()
        out = program.run(params=params)
        st = out["stats"]
        rows.append({
            "k": k,
            "tokens_per_s_per_slot": st["tokens_per_s_per_slot"],
            "tokens_per_s": st["tokens_per_s_per_slot"] * batch,
            "p50_ms": st["p50_ms"],
            "host_syncs": st["stall"]["host_syncs"],
            "stall_pct": st["stall"]["stall_pct"],
            "tokens": out["tokens"],
        })
    return rows


def main(smoke: bool = False) -> list[str]:
    import numpy as np

    if smoke:
        ks, batch, max_seq, max_new = (1, 4, 16), 2, 64, 32
    else:
        ks, batch, max_seq, max_new = (1, 4, 16, 64), 4, 256, 128
    rows = run(ks, batch, max_seq, max_new)
    # same config, same params: every K must decode the same tokens
    for r in rows[1:]:
        if not np.array_equal(r["tokens"], rows[0]["tokens"]):
            raise AssertionError(
                f"decode tokens diverged between K=1 and K={r['k']}")
    lines = []
    for r in rows:
        tps = r["tokens_per_s_per_slot"]
        us = 1e6 / tps if tps > 0 else float("nan")
        lines.append(
            f"decode/K{r['k']},{us:.1f},"
            f"tokens_per_s={r['tokens_per_s']:.1f};"
            f"stall_pct={r['stall_pct']:.1f};"
            f"host_syncs={r['host_syncs']};"
            f"batch={batch};max_new={max_new}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
