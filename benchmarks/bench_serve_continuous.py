"""Continuous vs static batching on a mixed-length serving workload.

The paper's headline is <2% execution stalls because shared-L1 slots are
always addressable and refilled while compute proceeds; the serving
analogue is slot occupancy. Static batching (the fixed-batch
ServeProgram/ServeLoop path) runs each batch to its slowest member, so a
slot that finishes its request early idles until the batch drains.
Continuous batching (ServeSession) recycles the slot at the next chunk
boundary. This bench runs the same request set — mixed prompt (1-8) and
output lengths drawn from {8..64}, right-skewed like real traffic —
through both paths on one slot pool and reports tokens/s, slot occupancy,
and p99 request latency.

Both paths share the decode cadence (chunk=K host-sync granularity) and
the same per-step model cost; the only difference is the admission
policy, so the ratio isolates the scheduling win.

A third scenario exercises the SLO layer: a mixed-class workload
(latency requests with deadlines arriving over a pool already full of
throughput work, plus more best-effort than the shed watermark admits)
reports per-class TTFT/latency percentiles, deadline misses, preemption
and retry counts — the rows `check_gate.py --require classes` enforces.

A fourth scenario exercises the shared paged KV pool (runtime/kvpool.py)
on an attention arch: the same shared-preamble workload runs through the
private-cache session and the paged session (`paged=True`), in waves so
TTFT is queue-free. Wave 1 runs cold (empty prefix cache); later waves
hit the published prefix pages and skip their prefill — the TTFT
collapse the tentpole claims — while `capacity_x` reports how many
concurrent requests the same pool memory holds relative to the private
per-slot reservation (measured from actual page allocs, so prefix
sharing counts).

A fifth scenario prices the durability layer (runtime/journal.py +
session snapshots + KV checksum scrub): the continuous workload runs
with and without `durable_dir` to measure the fsync'd-journal overhead
on fault-free tokens/s, a scripted crash + restore measures MTTR and
asserts exactly-once bit-identical completion, and a bit-flip on a
published prefix page must be detected and repaired before any request
reuses it — the row `check_gate.py --require recovery` enforces.

A sixth scenario measures cluster-of-clusters scaling: the same
per-group workload runs through `ShardedServeSessionProgram` at 1, 2,
and 4 groups, each measurement in a child process under
`--xla_force_host_platform_device_count=8` so every group owns a host
device. Aggregate tokens/s and per-group stall ledgers roll up into
`serve/groups_scaling`; scaling efficiency is normalized by the
*attainable* parallelism `min(groups, cores)` — on a multi-core host
that demands real near-linear scaling, on a single-core host (where G
device computes time-share one core and ideal aggregate throughput is
flat) it degenerates to a bound on the two-level scheduler's overhead.
The row records `cores=` so the gate and readers know which regime was
measured.

Row format: serve/{continuous|static},us_per_token,tokens_per_s=..;...
            serve/class_{latency|throughput|best_effort},p99_lat_us,...
            serve/slo,us_per_token,preemptions=..;retries=..;shed=..
            serve/paged_kv,us_per_token,tokens_per_s=..;capacity_x=..
            serve/prefix_reuse,warm_ttft_p50_us,ttft_speedup_x=..
            serve/recovery,mttr_us,mttr_ms=..;overhead_pct=..;
                bit_identical=1;exactly_once=1;violations=..;repairs=..
            serve/groups_scaling,us_per_token@4g,tps1=..;tps2=..;tps4=..;
                eff2=..;eff4=..;stall1=..;stall4=..;cores=..
"""

from __future__ import annotations

import time

ARCH = "xlstm-125m-smoke"
# the paged-KV scenario needs positional attention (recurrent archs keep
# their private per-slot state and reject paged mode)
PAGED_ARCH = "qwen3-14b-smoke"
PAGE_SIZE = 4
# right-skewed output-length mix on {8..64} (multiples of the chunk so the
# static path needs no tail-scan variants): mostly short, a long tail
OUT_LENS = (8, 8, 12, 16, 16, 24, 32, 64)
CHUNK = 4
SLOTS = 8
MAX_PROMPT = 8


def _workload(n_req: int, seed: int):
    import numpy as np

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, size=rng.integers(1, MAX_PROMPT + 1))
               .astype(np.int32) for _ in range(n_req)]
    outs = [int(v) for v in rng.choice(OUT_LENS, size=n_req)]
    return prompts, outs


def run_continuous(program, params, prompts, outs) -> dict:
    t0 = time.perf_counter()
    sess = program.open(params=params)
    handles = [sess.submit(p, n) for p, n in zip(prompts, outs)]
    sess.drain()
    wall = time.perf_counter() - t0
    st = sess.stats()
    useful = sum(h.tokens.size + p.size - 1
                 for h, p in zip(handles, prompts))   # prompt steps count too
    lats = sorted(h.latency_s for h in handles)
    import numpy as np
    return {
        "wall_s": wall,
        "useful_slot_steps": useful,
        "emitted": st["emitted_total"],
        "tokens_per_s": st["emitted_total"] / wall,
        "occupancy_pct": st["occupancy_pct"],
        "p99_ms": float(np.percentile(np.asarray(lats), 99) * 1e3),
        "ttft_p50_ms": st["ttft_ms"]["p50"],
    }


def run_classes(program, params, n_bulk: int, n_lat: int, seed: int) -> dict:
    """The SLO scenario: fill the pool with throughput work, overflow the
    shed watermark with best-effort, then land latency requests on the
    full pool mid-stream — preemption, shedding, and per-class accounting
    all fire deterministically (no wall-clock races: admission pressure
    comes from queue shape, not timing)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    sess = program.open(params=params)
    mk = lambda: rng.integers(0, 256, size=rng.integers(
        1, MAX_PROMPT + 1)).astype(np.int32)
    t0 = time.perf_counter()
    for _ in range(n_bulk):                      # bulk floor: long outputs
        sess.submit(mk(), 32, klass="throughput")
    for _ in range(n_bulk):                      # past the watermark: shed
        sess.submit(mk(), 16, klass="best_effort")
    for _ in range(2):                           # pool fills with bulk work
        sess.poll()
    for _ in range(n_lat):                       # latency lands on a full
        sess.submit(mk(), 8, klass="latency",    # pool -> preemption
                    deadline_s=30.0)
    sess.drain()
    wall = time.perf_counter() - t0
    st = sess.stats()
    st["wall_s"] = wall
    return st


def run_static(decode, engine, cfg, params, prompts, outs) -> dict:
    """The fixed-batch ServeProgram path (ServeLoop + DecodeEngine), gang-
    scheduled: groups of SLOTS requests run to the group's slowest member.
    The jitted decode step and the engine are shared across calls, so no
    recompiles ride in the timing."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import steps
    from repro.runtime.serve_loop import ServeLoop
    groups = [list(range(i, min(i + SLOTS, len(prompts))))
              for i in range(0, len(prompts), SLOTS)]
    max_seq = MAX_PROMPT + max(OUT_LENS) + 1
    wall = 0.0
    useful = total_slot_steps = 0
    lats = []
    for g in groups:
        g_prompts, g_outs = [prompts[i] for i in g], [outs[i] for i in g]
        max_p = max(p.size for p in g_prompts)
        max_n = max(g_outs)
        pad = np.zeros((SLOTS, max_p), np.int32)     # short prompts padded
        for r, p in enumerate(g_prompts):
            pad[r, :p.size] = p
        t0 = time.perf_counter()
        cache = steps.init_cache(cfg, SLOTS,
                                 steps.decode_cache_len(cfg, max_seq))
        tok = None
        for t in range(max_p):                       # batch prompt ingest
            cache, tok = decode(params, cache,
                                {"tokens": jnp.asarray(pad[:, t:t + 1]),
                                 "pos": jnp.asarray(t, jnp.int32)})
        loop = ServeLoop(decode, params, cache, batch_size=SLOTS,
                         eos_id=None, chunk=CHUNK, engine=engine)
        loop.generate(np.asarray(tok), max_new=max_n, start_pos=max_p)
        wall += time.perf_counter() - t0
        useful += sum(p.size + n for p, n in zip(g_prompts, g_outs))
        total_slot_steps += SLOTS * (max_p + max_n)
        lats += [wall] * len(g)            # a request lands when its group does
    # tokens/s counts USEFUL tokens: over-generated tail tokens past a
    # request's max_new are waste, not throughput
    useful_emitted = sum(outs)
    return {
        "wall_s": wall,
        "useful_slot_steps": useful,
        "emitted": useful_emitted,
        "tokens_per_s": useful_emitted / wall,
        "occupancy_pct": 100.0 * useful / total_slot_steps,
        "p99_ms": float(np.percentile(np.asarray(lats), 99) * 1e3),
    }


def run_paged(smoke: bool) -> list[str]:
    """Shared-preamble workload, private vs paged session, wave-by-wave
    (every request in a wave is admitted immediately, so TTFT measures
    prefill, not queueing)."""
    import numpy as np

    from repro.cluster import Cluster, ServeSessionProgram

    cluster = Cluster(PAGED_ARCH)
    slots, max_prompt, max_new = 4, 16, 8
    n_waves = 3 if smoke else 6
    max_seq = max_prompt + max_new + 1
    rng = np.random.default_rng(7)
    pre = rng.integers(0, 256, size=12).astype(np.int32)    # 3 full pages
    waves = [[np.concatenate([pre, rng.integers(0, 256, size=3)
                              .astype(np.int32)]) for _ in range(slots)]
             for _ in range(n_waves)]
    common = dict(slots=slots, max_seq=max_seq, max_prompt=max_prompt,
                  chunk=CHUNK)
    private = cluster.compile(ServeSessionProgram(preempt=False, **common))
    paged = cluster.compile(ServeSessionProgram(paged=True,
                                                page_size=PAGE_SIZE,
                                                **common))
    params = private.init_params()

    def run(program):
        sess = program.open(params=params)
        wave_ttfts = []
        t0 = time.perf_counter()
        for wave in waves:
            handles = [sess.submit(p, max_new) for p in wave]
            sess.drain()
            wave_ttfts.append([h.ttft_s for h in handles
                               if h.ttft_s is not None])
        wall = time.perf_counter() - t0
        return wall, sess.stats(), wave_ttfts

    run(private)                                # warm the compile caches
    run(paged)
    wall_p, st_p, _ = run(private)
    wall_g, st_g, ttfts = run(paged)

    kv = st_g["kv"]
    n_req = slots * n_waves
    # concurrent requests the private layout's memory holds when requests
    # allocate pages for their actual length (and share prefixes), vs the
    # per-slot max_seq reservation — measured from real allocs
    pps = -((max_seq + 1) // -PAGE_SIZE)
    capacity_x = pps * n_req / max(kv["allocs"], 1)
    cold = sorted(ttfts[0])
    warm = sorted(t for w in ttfts[1:] for t in w)
    cold_ms = 1e3 * cold[len(cold) // 2]
    warm_ms = 1e3 * warm[len(warm) // 2]
    tok_g = st_g["emitted_total"] / wall_g
    tok_p = st_p["emitted_total"] / wall_p
    return [
        f"serve/paged_kv,{1e6 / tok_g:.1f},"
        f"tokens_per_s={tok_g:.1f};private_tokens_per_s={tok_p:.1f};"
        f"capacity_x={capacity_x:.2f};pages_shared={kv['pages_shared']};"
        f"cow_forks={kv['cow_forks']};"
        f"pool_exhausted={kv['pool_exhausted']};"
        f"page_size={PAGE_SIZE};requests={n_req};slots={slots}",
        f"serve/prefix_reuse,{warm_ms * 1e3:.1f},"
        f"cold_ttft_p50_ms={cold_ms:.1f};warm_ttft_p50_ms={warm_ms:.1f};"
        f"ttft_speedup_x={cold_ms / max(warm_ms, 1e-9):.2f};"
        f"prefill_skipped={kv['prefill_skipped_tokens']};"
        f"prefix_hits={kv['prefix_hits']}",
    ]


def run_recovery(smoke: bool) -> list[str]:
    """The durability scenario: (a) journal + snapshot overhead on a
    fault-free run vs the plain session (same workload, same cell);
    (b) a scripted crash mid-decode followed by a measured restore
    (journal replay + snapshot load = MTTR) that must finish the
    workload exactly-once bit-identical; (c) a bit-flip on a shared
    page, caught by the checksum verify and repaired by recompute."""
    import shutil
    import tempfile

    import numpy as np

    from repro.cluster import Cluster, ServeSessionProgram
    from repro.runtime import FaultPlan
    from repro.runtime.faults import SessionCrashed
    from repro.runtime.journal import read_events, replay

    cluster = Cluster(ARCH)
    n_req = 128 if smoke else 192
    prompts, outs = _workload(n_req, seed=3)
    max_seq = MAX_PROMPT + max(OUT_LENS) + 1
    # chunk=16: the durability tax is per poll (journal flush, group-
    # commit fsync, amortized snapshot capture), so the overhead row is
    # priced at the coarse host-sync cadence a throughput deployment
    # runs — the same knob that amortizes host scheduling cost
    program = cluster.compile(ServeSessionProgram(
        slots=SLOTS, max_seq=max_seq, max_prompt=MAX_PROMPT, chunk=16,
        snapshot_every=12))
    params = program.init_params()

    def timed(durable_dir=None, fsync=None):
        sess = program.open(params=params, durable_dir=durable_dir,
                            journal_fsync=fsync)
        t0 = time.perf_counter()
        handles = [sess.submit(p, int(n)) for p, n in zip(prompts, outs)]
        sess.drain()
        wall = time.perf_counter() - t0
        st = sess.stats()
        sess.close()
        return st["emitted_total"] / wall, handles, st

    dur_dir = tempfile.mkdtemp()
    try:
        # The arms differ by a few percent while the host drifts by
        # about as much over a bench's lifetime, so neither best-of nor
        # arm-at-a-time medians measure the tax: rotate the arm order
        # each round (drift hits every arm equally) and compare per-arm
        # medians. "durable" is the group-commit configuration (fsync
        # every 12th poll, flush every poll: process-crash durable
        # always, bounded power-loss window); "strict" fsyncs per poll.
        timed()                                 # warm the compiled cell
        arms = {"plain": lambda i: timed(),
                "durable": lambda i: timed(f"{dur_dir}/nofault{i}", 12),
                "strict": lambda i: timed(f"{dur_dir}/strict{i}", True)}
        order = list(arms)
        runs = {k: [] for k in arms}
        rounds = 5 if smoke else 9
        for i in range(rounds):
            for k in order:
                runs[k].append(arms[k](i))
            order = order[1:] + order[:1]       # rotate: drift cancels

        def med_overhead(arm):
            # per-round pairwise ratio vs that round's plain run, median
            # over rounds: slow drift cancels within a round, the rotated
            # order cancels within-round position bias across rounds
            ovs = sorted(100.0 * (1.0 - runs[arm][i][0] / runs["plain"][i][0])
                         for i in range(rounds))
            return ovs[rounds // 2]

        tok_plain = sorted(r[0] for r in runs["plain"])[rounds // 2]
        tok_durable, _, st_d = sorted(runs["durable"],
                                      key=lambda r: r[0])[rounds // 2]
        expected = {h.id: [int(t) for t in h.result()]
                    for h in runs["plain"][0][1]}
        overhead = med_overhead("durable")
        strict_overhead = med_overhead("strict")

        # crash mid-decode, restore, drain: exactly-once, bit-identical
        crash_dir = dur_dir + "/crash"
        sess = program.open(params=params, durable_dir=crash_dir,
                            faults=FaultPlan().crash(at_chunk=18))
        for p, n in zip(prompts, outs):
            sess.submit(p, int(n))
        try:
            while sess.scheduler.busy or sess._pending_events:
                sess.poll()
            raise RuntimeError("crash fault never fired")
        except SessionCrashed:
            pass
        committed = {rid: list(r.committed) for rid, r in
                     replay(read_events(crash_dir + "/journal.jsonl"))
                     .requests.items()}
        sess2 = program.restore(crash_dir, params=params)
        du = sess2.stats()["durability"]
        final = {rid: list(t) for rid, t in committed.items()}
        for h, toks, done in sess2.stream():
            final.setdefault(h.id, []).extend(int(t) for t in toks)
        bit_identical = int(final == expected)  # also proves exactly-once:
        exactly_once = bit_identical            # a duplicate would lengthen
        du_after = sess2.stats()["durability"]  # some stream
    finally:
        shutil.rmtree(dur_dir, ignore_errors=True)

    # integrity: flip a published page between two prefix-sharing waves
    pcluster = Cluster(PAGED_ARCH)
    pprog = pcluster.compile(ServeSessionProgram(
        slots=4, max_seq=25, max_prompt=16, chunk=CHUNK, paged=True,
        page_size=PAGE_SIZE))
    rng = np.random.default_rng(11)
    pre = rng.integers(0, 256, size=12).astype(np.int32)
    psess = pprog.open(params=pprog.init_params())

    def pwave(tails):
        hs = [psess.submit(np.concatenate(
            [pre, np.asarray(t, np.int32)]), 8) for t in tails]
        psess.drain()
        return hs

    pwave([[1], [2]])
    psess.attach_faults(FaultPlan().bit_flip(at_chunk=psess._chunk_index))
    flip_handles = pwave([[3], [4]])
    pst = psess.stats()["durability"]
    nan_escapes = sum(not h.ok for h in flip_handles)

    mttr_ms = du["restore_s"] * 1e3
    return [
        f"serve/recovery,{mttr_ms * 1e3:.1f},"
        f"mttr_ms={mttr_ms:.2f};overhead_pct={overhead:.2f};"
        f"strict_overhead_pct={strict_overhead:.2f};"
        f"tokens_per_s={tok_plain:.1f};"
        f"durable_tokens_per_s={tok_durable:.1f};"
        f"replayed={du['replayed_requests']};"
        f"deduped={du_after['deduped_tokens']};"
        f"snapshots={st_d['durability']['snapshots']};"
        f"journal_bytes={st_d['durability']['journal_bytes']};"
        f"bit_identical={bit_identical};exactly_once={exactly_once};"
        f"violations={pst['integrity_violations']};"
        f"repairs={pst['integrity_repairs']};"
        f"nan_escapes={nan_escapes};requests={n_req}",
    ]


GROUP_SLOTS = 4                 # slots per serving group (full cell each)
GROUP_CHUNK = 16                # coarse cadence: device work dominates the
#   poll so group computes can actually overlap where cores allow
GROUP_OUT_LENS = (8, 8, 16, 24)


def _groups_child(n_groups: int, n_req: int, seed: int) -> None:
    """One groups-scaling measurement, meant to run in a child process
    under `--xla_force_host_platform_device_count=8` (so each group owns
    a host device). Prints a single JSON line."""
    import json
    import os

    import numpy as np

    from repro.cluster import Cluster, ShardedServeSessionProgram
    from repro.runtime.engine import StallClock

    cluster = Cluster(ARCH)
    max_seq = MAX_PROMPT + max(GROUP_OUT_LENS) + 1
    program = cluster.compile(ShardedServeSessionProgram(
        groups=n_groups, slots=GROUP_SLOTS, max_seq=max_seq,
        max_prompt=MAX_PROMPT, chunk=GROUP_CHUNK))
    params = program.init_params()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, size=rng.integers(1, MAX_PROMPT + 1))
               .astype(np.int32) for _ in range(n_req)]
    outs = [int(v) for v in rng.choice(GROUP_OUT_LENS, size=n_req)]

    # warm every group's compiled executable (first touch per device
    # compiles; keep that out of the timed region)
    warm = program.open(params=params)
    for g in range(n_groups):
        warm.groups[g].session.submit(prompts[0], GROUP_CHUNK)
    warm.drain()
    warm.close()

    sess = program.open(params=params)
    t0 = time.perf_counter()
    for p, n in zip(prompts, outs):
        sess.submit(p, n)
    st = sess.drain()
    wall = time.perf_counter() - t0
    per_stall = [st["groups"][g]["stall"]["stall_pct"]
                 for g in range(n_groups)]
    print(json.dumps({
        "groups": n_groups,
        "devices": len({id(d) for d in sess.plan.devices}),
        "cores": len(os.sched_getaffinity(0)),
        "emitted": st["emitted_total"],
        "wall_s": wall,
        "tokens_per_s": st["emitted_total"] / wall,
        "stall_pct": st["stall"]["stall_pct"],
        "stall_max_pct": max(per_stall),
        "occupancy_pct": st["occupancy_pct"],
        "placed": st["placement"]["placed"],
    }))
    sess.close()


def run_groups(smoke: bool) -> list[str]:
    """Cluster-of-clusters scaling: the same per-group workload at 1, 2,
    and 4 serving groups, one child process per point so each run gets a
    fresh 8-host-device XLA platform. Efficiency is aggregate tokens/s
    over `min(groups, cores)` times the 1-group rate — real scaling
    where the host has the cores, a scheduler-overhead bound where it
    does not (the row's `cores=` field says which was measured)."""
    import json
    import os
    import subprocess
    import sys

    per_group = 24 if smoke else 48
    rows = {}
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    for g in (1, 2, 4):
        out = subprocess.run(
            [sys.executable, __file__, "--groups-child", str(g),
             str(per_group * g), "5"],
            capture_output=True, text=True, env=env, check=True)
        rows[g] = json.loads(out.stdout.strip().splitlines()[-1])
    cores = rows[1]["cores"]
    tps = {g: rows[g]["tokens_per_s"] for g in (1, 2, 4)}
    eff = {g: tps[g] / (min(g, cores) * tps[1]) for g in (2, 4)}
    return [
        f"serve/groups_scaling,{1e6 / tps[4]:.1f},"
        f"tps1={tps[1]:.1f};tps2={tps[2]:.1f};tps4={tps[4]:.1f};"
        f"eff2={eff[2]:.3f};eff4={eff[4]:.3f};"
        f"stall1={rows[1]['stall_pct']:.2f};"
        f"stall4={rows[4]['stall_pct']:.2f};"
        f"stall4_max={rows[4]['stall_max_pct']:.2f};"
        f"cores={cores};devices={rows[4]['devices']};"
        f"slots_per_group={GROUP_SLOTS};chunk={GROUP_CHUNK};"
        f"requests_per_group={per_group}",
    ]


def main(smoke: bool = False) -> list[str]:
    import jax

    from repro.cluster import Cluster, ServeSessionProgram
    from repro.models import steps

    n_req = 24 if smoke else 48
    prompts, outs = _workload(n_req, seed=0)

    cluster = Cluster(ARCH)
    cfg = cluster.arch
    max_seq = MAX_PROMPT + max(OUT_LENS) + 1
    program = cluster.compile(ServeSessionProgram(
        slots=SLOTS, max_seq=max_seq, max_prompt=MAX_PROMPT, chunk=CHUNK))
    params = program.init_params()
    decode = jax.jit(steps.make_decode_step(cfg, max_seq=max_seq))
    from repro.runtime.engine import DecodeEngine
    engine = DecodeEngine(decode, CHUNK, eos_id=None)

    # warm both paths (compiles stay out of the timed region)
    w_prompts, w_outs = _workload(SLOTS, seed=1)
    run_continuous(program, params, w_prompts, w_outs)
    run_static(decode, engine, cfg, params, w_prompts[:SLOTS],
               w_outs[:SLOTS])

    cont = run_continuous(program, params, prompts, outs)
    stat = run_static(decode, engine, cfg, params, prompts, outs)

    # SLO scenario: same cell, priority admission + preemption + shedding
    n_bulk = 8 if smoke else 16
    n_lat = 4 if smoke else 8
    slo_program = cluster.compile(ServeSessionProgram(
        slots=SLOTS, max_seq=max_seq, max_prompt=MAX_PROMPT, chunk=CHUNK,
        shed_watermark=n_bulk + n_bulk // 2, preempt=True))
    slo = run_classes(slo_program, params, n_bulk, n_lat, seed=2)

    lines = []
    for name, r in (("continuous", cont), ("static", stat)):
        us = 1e6 / r["tokens_per_s"] if r["tokens_per_s"] > 0 else float("nan")
        extra = (f";ttft_p50_ms={r['ttft_p50_ms']:.1f}"
                 if "ttft_p50_ms" in r else "")
        lines.append(
            f"serve/{name},{us:.1f},"
            f"tokens_per_s={r['tokens_per_s']:.1f};"
            f"occupancy_pct={r['occupancy_pct']:.1f};"
            f"p99_ms={r['p99_ms']:.1f}{extra};"
            f"requests={n_req};slots={SLOTS};chunk={CHUNK}")
    for klass in ("latency", "throughput", "best_effort"):
        c = slo["classes"][klass]
        lines.append(
            f"serve/class_{klass},{c['latency_ms']['p99'] * 1e3:.1f},"
            f"ttft_p50_ms={c['ttft_ms']['p50']:.1f};"
            f"ttft_p99_ms={c['ttft_ms']['p99']:.1f};"
            f"p99_ms={c['latency_ms']['p99']:.1f};"
            f"deadline_miss={c['deadline_miss']};"
            f"done={c['done']};submitted={c['submitted']};"
            f"preempted={c['preempted']};shed={c['shed']}")
    slo_us = (1e6 / slo["tokens_per_s"] if slo["tokens_per_s"] > 0
              else float("nan"))
    lines.append(
        f"serve/slo,{slo_us:.1f},"
        f"tokens_per_s={slo['tokens_per_s']:.1f};"
        f"preemptions={slo['preemptions']};retries={slo['retries']};"
        f"shed={slo['requests_shed']};deadline_miss={slo['deadline_miss']};"
        f"requests_done={slo['requests_done']};"
        f"occupancy_pct={slo['occupancy_pct']:.1f}")
    lines += run_paged(smoke)
    lines += run_recovery(smoke)
    lines += run_groups(smoke)
    return lines


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1 and sys.argv[1] == "--groups-child":
        _groups_child(int(sys.argv[2]), int(sys.argv[3]),
                      int(sys.argv[4]) if len(sys.argv) > 4 else 5)
    else:
        print("\n".join(main(smoke=True)))
