"""Paper Fig. 15 — double-buffered execution: steady-state overlap.

Measures the data/prefetch.py feed: producer ("DMA") time per batch vs
consumer ("compute") time per step, serial vs overlapped wall time, and the
steady-state utilization — the paper's compute-phase occupancy.
"""

from __future__ import annotations

import time

import numpy as np

from repro.data import DoubleBufferedFeed


def run(produce_s: float, compute_s: float, steps: int = 12) -> dict:
    def make(step):
        time.sleep(produce_s)
        return {"step": step}

    feed = DoubleBufferedFeed(make, depth=2)
    t0 = time.perf_counter()
    for _ in range(steps):
        next(feed)
        time.sleep(compute_s)
    wall = time.perf_counter() - t0
    ledger = feed.stall_report()          # producer busy vs consumer blocked
    feed.close()
    serial = steps * (produce_s + compute_s)
    ideal = steps * max(produce_s, compute_s)
    return {"wall": wall, "serial": serial, "ideal": ideal,
            "overlap_efficiency": (serial - wall) / (serial - ideal + 1e-9),
            "compute_util": steps * compute_s / wall,
            "dma_overlap_pct": ledger["overlap_pct"]}


def main(smoke: bool = False) -> list[str]:
    lines = []
    steps = 4 if smoke else 12
    scale = 0.25 if smoke else 1.0
    for name, (p, c) in {
        "compute_bound": (0.005, 0.02),     # paper: matmul/dct rounds
        "balanced": (0.01, 0.01),
        "transfer_bound": (0.02, 0.007),    # paper: axpy/dotp (L2-bound)
    }.items():
        r = run(p * scale, c * scale, steps=steps)
        lines.append(
            f"fig15/{name},{r['wall'] * 1e6 / steps:.0f},"
            f"compute_util={r['compute_util']:.2f};"
            f"overlap_eff={max(min(r['overlap_efficiency'], 1.5), 0):.2f};"
            f"dma_overlap={r['dma_overlap_pct']:.0f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
